"""Canonical torus/cuboid geometry — the single home of cut/interior math.

This module owns every pure-geometry primitive used across the repo:
canonical forms, factorizations, cuboid containment, exact cuboid cut and
interior edge counts, and exact bisection search.  It was extracted from
``repro.core.torus`` so that the contention, collectives, allocation and
launch layers all share one implementation (see DESIGN.md).

Conventions
-----------
* A torus is described by its dimension lengths ``dims = (a_1, ..., a_D)``.
* Geometries are canonicalised in *sorted descending* order, matching the
  paper's canonical representation (partitions identical up to rotation are
  treated as one).
* A dimension of length 2 is a *double link* under the Blue Gene/Q
  convention: both the +1 and -1 neighbour coincide, contributing two
  parallel edges.  TPU ICI fabrics use a single link instead — that switch
  lives in :class:`repro.network.fabric.TorusFabric`; the functions here
  implement the fully-wrapped double-link (paper) convention unless noted.
* Dimensions of length 1 contribute no edges (self-loops are excluded).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Iterable, Iterator, List, Sequence, Tuple

Geometry = Tuple[int, ...]


def canonical(dims: Iterable[int]) -> Geometry:
    """Sorted-descending canonical form of a torus/cuboid geometry."""
    out = tuple(sorted((int(d) for d in dims), reverse=True))
    if any(d < 1 for d in out):
        raise ValueError(f"dimension lengths must be >= 1, got {out}")
    return out


def volume(dims: Iterable[int]) -> int:
    """Vertex count of the torus/cuboid: the product of dimension lengths."""
    return math.prod(dims)


def degree_contribution(length: int) -> int:
    """Edges incident to a vertex along one torus dimension of given length."""
    if length == 1:
        return 0
    return 2  # length==2 is a double link; still two edge-endpoints per vertex.


def degree(dims: Sequence[int]) -> int:
    """Vertex degree of the (regular) torus with the given dimension lengths."""
    return sum(degree_contribution(a) for a in dims)


def num_edges(dims: Sequence[int]) -> int:
    """Undirected edge count, honouring the double-link convention for a==2."""
    total = 0
    n = volume(dims)
    for a in dims:
        if a == 1:
            continue
        lines = n // a
        edges_per_line = a if a > 2 else 2
        total += lines * edges_per_line
    return total


# ---------------------------------------------------------------------------
# Cuboid containment / cut / interior.
# ---------------------------------------------------------------------------
def contains_cuboid(torus_dims: Sequence[int], cuboid: Sequence[int]) -> bool:
    """Whether a cuboid geometry fits in the torus (up to rotation)."""
    t = canonical(torus_dims)
    c = canonical(cuboid)
    if len(c) > len(t):
        return False
    c = c + (1,) * (len(t) - len(c))
    # Greedy matching on sorted-descending lists is exact here: match the
    # largest cuboid side to the smallest torus side that still fits.
    avail = list(t)
    for side in c:
        candidates = [i for i, a in enumerate(avail) if a >= side]
        if not candidates:
            return False
        best = min(candidates, key=lambda i: avail[i])
        avail.pop(best)
    return True


def cuboid_cut(torus_dims: Sequence[int], cuboid: Sequence[int]) -> int:
    """|E(S, S̄)| for a cuboid subset S, counting double links for a_i == 2.

    A cuboid side s_i embedded in torus dimension a_i contributes:
      * 0 edges if s_i == a_i (the dimension is fully covered; wrap-around
        links are internal),
      * 2 * |S| / s_i edges otherwise (one +face and one -face, which is
        also exact for s_i == 1 whether or not a_i == 2, by the
        double-link convention).

    The cut depends on which torus dimension each side is embedded in
    (only via full coverage); we return the minimum over all feasible
    embeddings, which is the cut of the canonical geometry.
    """
    t = canonical(torus_dims)
    c = list(canonical(cuboid))
    if len(c) > len(t):
        raise ValueError(f"cuboid {c} has more dims than torus {t}")
    c = c + [1] * (len(t) - len(c))
    if not contains_cuboid(t, c):
        raise ValueError(f"cuboid {tuple(c)} does not fit in torus {t}")
    size = volume(c)
    best = None
    for perm in set(itertools.permutations(c)):
        if any(s > a for s, a in zip(perm, t)):
            continue
        cut = sum(2 * size // s for s, a in zip(perm, t) if s != a)
        best = cut if best is None else min(best, cut)
    assert best is not None
    return best


def cuboid_cut_aligned(torus_dims: Sequence[int], sides: Sequence[int]) -> int:
    """Cut of a cuboid with side i embedded along torus dimension i
    (no canonicalisation — for validation against explicit placements)."""
    t = tuple(int(a) for a in torus_dims)
    s = tuple(sides) + (1,) * (len(t) - len(tuple(sides)))
    if any(x > a for x, a in zip(s, t)):
        raise ValueError(f"aligned cuboid {s} does not fit in {t}")
    size = volume(s)
    return sum(2 * size // x for x, a in zip(s, t) if x != a)


def cuboid_interior(torus_dims: Sequence[int], cuboid: Sequence[int]) -> int:
    """|E(S, S)| for a cuboid subset, via the regularity identity (Eq. 1):
    k*|S| = 2|E(S,S)| + |E(S, S̄)| for a k-regular graph."""
    t = canonical(torus_dims)
    c = canonical(tuple(cuboid) + (1,) * (len(t) - len(tuple(cuboid))))
    size = volume(c)
    k = degree(t)
    cut = cuboid_cut(t, c)
    twice_interior = k * size - cut
    assert twice_interior % 2 == 0
    return twice_interior // 2


def sub_cuboids(torus_dims: Sequence[int], size: int) -> Iterator[Geometry]:
    """All canonical cuboid geometries of a given vertex count that fit."""
    t = canonical(torus_dims)
    seen = set()
    for c in factorizations(size, len(t)):
        if c in seen:
            continue
        seen.add(c)
        if contains_cuboid(t, c):
            yield c


def bisection_links(dims: Sequence[int]) -> int:
    """Internal bisection bandwidth of a fully-wrapped torus in links.

    By the edge-isoperimetric bound the minimum bisection of a torus with
    an even-length longest dimension is attained by halving the longest
    dimension: 2 * N / L links (the paper's Blue Gene/Q formula).
    For an odd longest dimension we take floor(N/2)-sized near-halves and
    search cuboids exactly.
    """
    t = canonical(dims)
    n = volume(t)
    if n == 1:
        return 0
    L = t[0]
    if L % 2 == 0:
        return 2 * n // L
    if L == 1:
        return 0
    target = n // 2
    best = None
    for c in sub_cuboids(t, target):
        cut = cuboid_cut(t, c)
        best = cut if best is None else min(best, cut)
    if best is None:
        # No cuboid of size exactly floor(n/2) exists; use the analytic
        # isoperimetric lower bound (conservative for reporting).
        best = math.ceil(theorem31_bound(t, target))
    return best


def theorem31_bound(dims: Sequence[int], t: int) -> float:
    """Theorem 3.1: the generalized edge-isoperimetric lower bound.

    ``dims`` are the torus dimension lengths (any order; canonicalised to
    a_1 >= a_2 >= ... >= a_D).  For a cuboid S with |S| = t:

        |E(S, S̄)| >= min_{r in 0..D-1}
            2 (D - r) * (prod of the r smallest dims)^(1/(D-r)) * t^((D-r-1)/(D-r))

    This is the single implementation; ``repro.core.isoperimetry`` re-exports
    it alongside the rest of the paper's analysis.
    """
    a = canonical(dims)
    n = volume(a)
    if t < 0 or t > n // 2:
        raise ValueError(f"t must satisfy 0 <= t <= |V|/2 = {n // 2}, got {t}")
    if t == 0:
        return 0.0
    D = len(a)
    best = math.inf
    for r in range(D):
        k = math.prod(a[D - r:]) if r > 0 else 1  # product of r smallest dims
        val = 2.0 * (D - r) * k ** (1.0 / (D - r)) * t ** ((D - r - 1.0) / (D - r))
        best = min(best, val)
    return best


# ---------------------------------------------------------------------------
# Enumeration.
# ---------------------------------------------------------------------------
def factorizations(n: int, max_parts: int) -> Iterator[Geometry]:
    """All multisets of <= max_parts integers >= 1 whose product is n.

    Yields canonical (sorted descending) tuples padded to max_parts with 1s.
    """

    def rec(remaining: int, max_factor: int, parts: Tuple[int, ...]) -> Iterator[Tuple[int, ...]]:
        if len(parts) == max_parts:
            if remaining == 1:
                yield parts
            return
        for f in range(min(remaining, max_factor), 0, -1):
            if remaining % f == 0:
                yield from rec(remaining // f, f, parts + (f,))

    for combo in rec(n, n, ()):  # descending by construction
        yield combo


def all_divisor_geometries(n: int, D: int) -> List[Geometry]:
    """All canonical cuboid geometries of n vertices with <= D dimensions,
    sorted descending (most elongated first)."""
    return sorted(set(factorizations(n, D)), reverse=True)


def enumerate_vertices(dims: Sequence[int]) -> Iterator[Tuple[int, ...]]:
    """All vertex coordinate tuples, in C (row-major, last dim fastest) order."""
    yield from itertools.product(*(range(a) for a in dims))


# ---------------------------------------------------------------------------
# Brute-force validation torus.
# ---------------------------------------------------------------------------
@dataclass
class ExplicitTorus:
    """Small explicit torus used for brute-force validation in tests.

    Unlike the closed-form functions above, this builds vertex/edge sets
    explicitly, so that cut counting for *arbitrary* (non-cuboid) subsets can
    be cross-checked.  Multi-edges for length-2 dimensions are honoured.
    """

    dims: Tuple[int, ...]
    _edges: list = field(default_factory=list)

    def __post_init__(self):
        self.dims = tuple(int(d) for d in self.dims)
        edges = []
        for v in enumerate_vertices(self.dims):
            for k, a in enumerate(self.dims):
                if a == 1:
                    continue
                w = list(v)
                w[k] = (v[k] + 1) % a
                w = tuple(w)
                edges.append((v, w))
                if a == 2 and v[k] == 0:
                    edges.append((v, w))  # double link
        # every undirected edge appended once per +1 step; for a>2 this counts
        # each ring edge exactly once, for a==2 the pair (0,1) gets two edges.
        if any(a == 2 for a in self.dims):
            # For a==2 dims: v[k]=0 appends (0->1) twice, v[k]=1 appends (1->0)
            # once == duplicate of (0,1). Filter: keep edges from v[k]<w[k] side.
            filt = []
            for (v, w) in edges:
                ks = [k for k in range(len(self.dims)) if v[k] != w[k]]
                k = ks[0]
                if self.dims[k] == 2 and v[k] != 0:
                    continue
                filt.append((v, w))
            edges = filt
        self._edges = edges

    @property
    def num_vertices(self) -> int:
        return volume(self.dims)

    @property
    def num_edges(self) -> int:
        return len(self._edges)

    def cut(self, subset: Iterable[Tuple[int, ...]]) -> int:
        s = set(subset)
        return sum(1 for (v, w) in self._edges if (v in s) != (w in s))

    def interior(self, subset: Iterable[Tuple[int, ...]]) -> int:
        s = set(subset)
        return sum(1 for (v, w) in self._edges if v in s and w in s)

    def cuboid_vertices(self, cuboid: Sequence[int]) -> List[Tuple[int, ...]]:
        c = tuple(cuboid) + (1,) * (len(self.dims) - len(tuple(cuboid)))
        # place cuboid at origin, side i along dim i (caller aligns sides)
        for side, a in zip(c, self.dims):
            if side > a:
                raise ValueError(f"{c} does not fit in {self.dims} as aligned")
        return list(itertools.product(*(range(s) for s in c)))
