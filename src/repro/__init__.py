"""repro: Network Partitioning and Avoidable Contention — a multi-pod JAX
training/inference framework with isoperimetric partition-aware allocation."""

__version__ = "1.1.0"
