"""repro.utils — small cross-cutting helpers.

``repro.utils.env`` configures the jax computation environment (x64
precision, platform, host device count) for the compiled network backends
and the kernel layers; nothing here imports jax at module scope, so the
package stays importable on numpy-only installs.
"""

from .env import (
    have_jax,
    jax_enable_x64,
    set_host_device_count,
    set_platform,
)

__all__ = [
    "have_jax",
    "jax_enable_x64",
    "set_host_device_count",
    "set_platform",
]
