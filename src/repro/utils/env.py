"""Computation-environment configuration for the jax-backed layers.

The compiled network backends (:mod:`repro.network.backend`) and the
kernel/roofline layers share two environment concerns:

* **Precision** — the network engines are exact in float64/int64, so any
  jit-compiled port must run under ``jax_enable_x64``; a silent fall back
  to float32 would turn exact link-load identities into approximations.
* **Topology** — tests and benchmarks sometimes want a specific platform
  (``cpu``) or a multi-device host (``--xla_force_host_platform_device_count``)
  regardless of what hardware jax detects.

All helpers degrade gracefully: importing this module never imports jax,
and each setter raises ``RuntimeError`` with a clear message when jax is
missing rather than an opaque ``ImportError`` deep inside a backend.

>>> have_jax() in (True, False)
True
"""

from __future__ import annotations

import importlib.util
import os


def have_jax() -> bool:
    """Whether jax is importable in this environment (spec lookup only —
    does not import jax, so calling this is always cheap and safe)."""
    return importlib.util.find_spec("jax") is not None


def _require_jax():
    if not have_jax():
        raise RuntimeError(
            "jax is not installed; install jax[cpu] or use the numpy backend"
        )
    import jax

    return jax


def jax_enable_x64(enable: bool = True) -> None:
    """Set jax's default array precision to 64-bit (or back to 32).

    With ``enable=False`` the ``JAX_ENABLE_X64`` environment variable is
    consulted before switching off, matching the upstream convention that
    the environment wins over a programmatic opt-out.  The flag is
    process-global; the compiled network backends call this on first use
    because their exactness contracts (integer link loads, int64 cut
    arithmetic) require 64-bit types.
    """
    if not enable:
        enable = bool(os.getenv("JAX_ENABLE_X64", 0))
    _require_jax().config.update("jax_enable_x64", bool(enable))


def set_platform(platform: str = "cpu") -> None:
    """Pin jax to one platform (``cpu``, ``gpu`` or ``tpu``).

    Only takes effect before jax initialises its backends — call it at
    program start (benchmarks do, so timing never silently lands on an
    accelerator with different float semantics).
    """
    _require_jax().config.update("jax_platform_name", platform)


def set_host_device_count(n: int) -> None:
    """Force the host CPU platform to expose ``n`` devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count``.

    Must run before jax initialises; existing unrelated ``XLA_FLAGS``
    content is preserved.  Useful for exercising multi-device mesh code
    paths on a single machine.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    flags = os.environ.get("XLA_FLAGS", "")
    parts = [
        f for f in flags.split() if not f.startswith("--xla_force_host_platform_device_count")
    ]
    parts.append(f"--xla_force_host_platform_device_count={n}")
    os.environ["XLA_FLAGS"] = " ".join(parts)
