"""Attribute compiled-HLO collectives to logical mesh axes, and price them
with the paper's contention model.

The SPMD partitioner tags every collective with ``replica_groups``.  For a
row-major device mesh (pod, data, model) the *minor* axis ("model") forms
contiguous groups (stride 1), "data" strides by |model|, and "pod" by
|data|*|model|.  XLA emits groups either as an explicit list
(``{{0,1,...},{...}}``) or in iota form (``[G,N]<=[A,B,...]T(perm)``); both
are parsed here and classified by (group size, stride).

The contention-aware collective term then prices each axis with its physical
embedding (launch/mesh.plan_axes): wrapped ICI ring (2 directions x 50 GB/s),
chain (1x), or the cross-pod DCI (12.5 GB/s) — this is where the paper's
geometry/assignment analysis enters the roofline.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .roofline import _OP_RE, _type_bytes, LINK_BW

_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_signature(line: str) -> Optional[Tuple[int, int]]:
    """(group_size, stride) of the first replica group, if parseable."""
    m = _IOTA_RE.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else list(range(len(dims)))
        # devices = iota(prod(dims)).reshape(dims).transpose(perm).reshape(G, N)
        # stride of consecutive members in a group = stride of the last
        # transposed dimension in the original layout.
        strides = [1] * len(dims)
        for i in range(len(dims) - 2, -1, -1):
            strides[i] = strides[i + 1] * dims[i + 1]
        last_dim = perm[-1]
        return group_size, strides[last_dim]
    m = _LIST_RE.search(line)
    if m:
        members = [int(x) for x in m.group(1).split(",")]
        if len(members) < 2:
            return len(members), 1
        return len(members), members[1] - members[0]
    return None


def classify_axis(
    group_size: int, stride: int, mesh_shape: Dict[str, int]
) -> str:
    """Map (group size, stride) to a mesh axis (or axis product) name."""
    names = list(mesh_shape)
    sizes = [mesh_shape[n] for n in names]
    # minor-to-major strides in a row-major mesh
    strides = {}
    acc = 1
    for n in reversed(names):
        strides[n] = acc
        acc *= mesh_shape[n]
    for n in names:
        if group_size == mesh_shape[n] and stride == strides[n]:
            return n
    # axis products (e.g. ("pod","data") fsdp groups)
    for i in range(len(names)):
        for j in range(i + 1, len(names) + 1):
            prod = 1
            for n in names[i:j]:
                prod *= mesh_shape[n]
            if group_size == prod and stride in (strides[names[j - 1]], 1):
                return "+".join(names[i:j])
    if group_size == acc:
        return "ALL"
    return f"unknown({group_size},{stride})"


def per_axis_collectives(
    hlo_text: str, mesh_shape: Dict[str, int]
) -> Dict[str, Dict[str, float]]:
    """axis -> {bytes, count} summed over all collective ops."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        sig = _group_signature(line)
        axis = classify_axis(sig[0], sig[1], mesh_shape) if sig else "unknown"
        b = _type_bytes(m.group(1))
        slot = out.setdefault(axis, {"bytes": 0.0, "count": 0})
        slot["bytes"] += b
        slot["count"] += 1
    return out


# ---------------------------------------------------------------------------
# Contention-aware pricing (the paper's model applied to the roofline)
# ---------------------------------------------------------------------------
DCI_BW = 12.5e9  # cross-pod per-chip share


@dataclass(frozen=True)
class AxisBandwidth:
    name: str
    effective_bw: float  # bytes/s available to one chip's collective stream
    why: str


def axis_bandwidths(
    mesh_shape: Dict[str, int], model_gets_best_rings: bool = True
) -> Dict[str, AxisBandwidth]:
    """Physical bandwidth per logical axis under an assignment plan.

    Paper-faithful planning (`model_gets_best_rings=True`) gives the
    heavy-traffic "model" axis the wrapped contiguous ICI rings (2 x LINK_BW)
    and "data" the second dimension's rings (also wrapped on a full pod).
    The naive plan (False) models an allocator that hands "model" a strided /
    chain embedding: half the effective bandwidth — the TPU analogue of the
    paper's elongated-partition penalty.
    """
    out = {}
    for name in mesh_shape:
        if name == "pod":
            out[name] = AxisBandwidth(name, DCI_BW, "cross-pod DCI")
        elif name == "model":
            bw = 2 * LINK_BW if model_gets_best_rings else LINK_BW
            out[name] = AxisBandwidth(
                name, bw, "wrapped ICI ring" if model_gets_best_rings else "chain/strided embedding"
            )
        else:
            out[name] = AxisBandwidth(name, 2 * LINK_BW, "wrapped ICI ring")
    return out


def contention_aware_collective_term(
    per_axis: Dict[str, Dict[str, float]],
    mesh_shape: Dict[str, int],
    model_gets_best_rings: bool = True,
) -> Tuple[float, Dict[str, float]]:
    """Seconds per step, per-device, pricing each axis with its embedding."""
    bws = axis_bandwidths(mesh_shape, model_gets_best_rings)
    per_axis_time = {}
    for axis, stat in per_axis.items():
        parts = axis.split("+")
        # an axis-product collective (fsdp groups) is bottlenecked by its
        # slowest member; 'ALL'/'unknown' get the conservative single link
        if axis == "ALL" or axis.startswith("unknown"):
            bw = LINK_BW
        else:
            bw = min(bws[p].effective_bw for p in parts if p in bws) if all(
                p in bws for p in parts
            ) else LINK_BW
        per_axis_time[axis] = stat["bytes"] / bw
    return sum(per_axis_time.values()), per_axis_time
