from . import analytic, roofline
from .axis_attribution import (
    per_axis_collectives,
    contention_aware_collective_term,
    classify_axis,
)
