"""Three-term roofline analysis from compiled XLA artifacts.

    compute term    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes / (chips * HBM_BW)
    collective term = collective_bytes / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  XLA reports
*per-device* numbers for SPMD executables, so the global quantities are
per_device * chips — the chips cancel; we keep the prompt's normalisation
explicit in :func:`roofline_terms`.

collective_bytes is not in cost_analysis: :func:`collective_stats` parses
the post-partitioning HLO (``compiled.as_text()``) and sums the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, per op type.  Result bytes are the standard proxy for
ring traffic (an n-chip ring all-gather moves (n-1)/n of the result bytes
per link — the (n-1)/n ≈ 1 factor is folded into the model's error bars).

Hardware model: TPU v5e-class (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link/direction).
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.network.fabric import DEFAULT_LINK_BW

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = DEFAULT_LINK_BW  # bytes/s per ICI link per direction (repro.network)


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalised across jax versions.

    Older jax returns a list with one dict per program; newer returns the
    dict directly.  Always returns a (possibly empty) dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[16,512,128]{2,1,0} all-gather(%x), ...
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[\w\[\],{}:#\s]*?))\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"(?:\.\d+)?\("
)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-type {count, bytes} from post-partitioning HLO text."""
    out: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "bytes": 0} for c in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op.replace("-start", "")
        if base not in out:
            continue
        out[base]["count"] += 1
        out[base]["bytes"] += _type_bytes(type_str)
    return out


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return float(sum(v["bytes"] for v in stats.values()))


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities from the compiled artifact
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: Dict[str, Dict[str, float]]
    # model-level accounting
    model_flops: float  # 6*N*D (dense) or 6*N_active*D per step, global
    # memory accounting
    bytes_per_device: Optional[float] = None
    notes: str = ""

    # -- the three terms (seconds) ------------------------------------------------
    @property
    def compute_term(self) -> float:
        return self.hlo_flops * self.chips / (self.chips * PEAK_FLOPS)

    @property
    def memory_term(self) -> float:
        return self.hlo_bytes * self.chips / (self.chips * HBM_BW)

    @property
    def collective_term(self) -> float:
        return self.collective_bytes * self.chips / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_term,
            "memory": self.memory_term,
            "collective": self.collective_term,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.compute_term, self.memory_term, self.collective_term)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs: how much compiled compute is useful."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOPs over the bound-time's compute."""
        t = self.step_time_lower_bound
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)

    def to_json(self) -> Dict[str, Any]:
        d = asdict(self)
        d.update(
            compute_term=self.compute_term,
            memory_term=self.memory_term,
            collective_term=self.collective_term,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def model_flops_per_step(
    n_params_matmul: float, tokens: float, moe_active_fraction: float = 1.0,
    training: bool = True,
) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference forward."""
    mult = 6.0 if training else 2.0
    return mult * n_params_matmul * moe_active_fraction * tokens


def matmul_param_count(params_shapes) -> float:
    """Parameters participating in matmuls (ndim >= 2 after stacking dims)."""
    import jax

    total = 0
    for leaf in jax.tree.leaves(params_shapes):
        if leaf.ndim >= 2:
            total += leaf.size
    return float(total)
