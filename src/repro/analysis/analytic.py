"""Exact analytic FLOP / byte accounting per architecture and cell.

Why this exists: XLA's ``cost_analysis`` counts each ``while``-loop body
once, so any scanned program (layer stacks, online-softmax KV loops, SSD
chunk scans) is undercounted by its trip counts.  The dry-run therefore
derives compute/memory roofline terms from this *analytic* model — exact
closed forms of the matmul/attention/scan math as compiled (including remat
recompute, the causal full-mask waste of the XLA attention path, and MoE
capacity overhead) — and the model is validated against
``compiled.cost_analysis()`` on small fully-unrolled configs where XLA's
count is exact (tests/test_roofline.py).

Collective bytes are NOT modelled here: they come from the compiled HLO of
unrolled calibration lowers (see launch/dryrun.py) where counting is exact.

Conventions: a matmul of (m, k) x (k, n) costs 2*m*k*n FLOPs; bytes are
HBM traffic estimates with bf16 activations/params and f32 scan states.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import ArchConfig, ShapeConfig

BF16 = 2
F32 = 4

# must match the defaults in models/ (layers.attention_xla kv_block, rwkv
# chunk, ssd chunk)
ATTN_KV_BLOCK = 1024
RWKV_CHUNK = 32


@dataclass
class CellCost:
    flops_compiled: float  # as-compiled global FLOPs per step
    flops_useful: float  # model FLOPs (6ND-convention, causal-exact attention)
    bytes_hbm: float  # estimated global HBM traffic per step
    breakdown: Dict[str, float]


def _attn_flops(arch: ArchConfig, B: int, S: int, compiled: bool) -> float:
    """Scores + PV flops for the train/prefill attention over S tokens."""
    H, hd = arch.n_heads, arch.resolved_head_dim
    if arch.sliding_window is not None and S > arch.sliding_window:
        band = min(arch.sliding_window + ATTN_KV_BLOCK, S)
        kv_len = band if compiled else min(arch.sliding_window, S) / 2 + ATTN_KV_BLOCK / 2
    else:
        kv_len = S if compiled else S / 2  # causal: useful is half
    return 2 * 2 * B * S * kv_len * H * hd


def _qkvo_flops(arch: ArchConfig, tokens: float) -> float:
    d, H, K, hd = arch.d_model, arch.n_heads, arch.n_kv_heads, arch.resolved_head_dim
    return 2 * tokens * (d * H * hd + 2 * d * K * hd + H * hd * d)


def _mlp_flops(arch: ArchConfig, tokens: float) -> float:
    glu = 3 if arch.mlp_act.endswith("_glu") else 2
    return 2 * tokens * glu * arch.d_model * arch.d_ff


def _moe_flops(arch: ArchConfig, tokens: float, compiled: bool) -> float:
    moe = arch.moe
    glu = 3 if arch.mlp_act.endswith("_glu") else 2
    mult = moe.top_k * (moe.capacity_factor if compiled else 1.0)
    expert = 2 * tokens * mult * glu * arch.d_model * arch.d_ff
    router = 2 * tokens * arch.d_model * moe.num_experts
    return expert + router


def _rwkv_layer_flops(arch: ArchConfig, B: int, S: int) -> float:
    d = arch.d_model
    P = arch.rwkv.head_dim
    H = d // P
    lora = arch.rwkv.decay_lora
    proj = 2 * B * S * 5 * d * d  # r,k,v,g,o
    dd = 2 * B * S * (d * lora + lora * d)
    Q = min(RWKV_CHUNK, S)
    n = math.ceil(S / Q)
    # per chunk per head: scores direct form ~ 3*Q^2*P (mult+exp treated as 1)
    # + scores@v 2*Q^2*P + state in/out 2*2*Q*P^2
    wkv = B * H * n * (3 * Q * Q * P + 2 * Q * Q * P + 4 * Q * P * P)
    cm = 2 * B * S * 2 * arch.d_model * arch.d_ff
    return proj + dd + wkv + cm


def _mamba_layer_flops(arch: ArchConfig, B: int, S: int) -> float:
    s = arch.ssm
    d = arch.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    P, N, G = s.head_dim, s.state_dim, s.n_groups
    proj_out = 2 * d_in + 2 * G * N + H
    proj = 2 * B * S * d * proj_out + 2 * B * S * d_in * d
    Q = min(s.chunk, S)
    n = math.ceil(S / Q)
    # per chunk per head: CB^T 2Q^2N + (scores*L)@x 2Q^2P + state 2*2*Q*N*P
    ssd = B * H * n * (2 * Q * Q * N + 2 * Q * Q * P + 4 * Q * N * P)
    conv = B * S * (d_in + 2 * G * N) * s.conv_width * 2
    return proj + ssd + conv


def _head_flops(arch: ArchConfig, B: int, S: int) -> float:
    return 2 * B * S * arch.d_model * arch.padded_vocab_size * arch.n_codebooks


def forward_flops(arch: ArchConfig, B: int, S: int, compiled: bool = True) -> Dict[str, float]:
    """Per-component forward flops for B sequences of S tokens."""
    tokens = B * S
    L = arch.n_layers
    out: Dict[str, float] = {}
    if arch.family == "ssm" and arch.rwkv is not None:
        out["layers"] = L * _rwkv_layer_flops(arch, B, S)
    elif arch.family == "hybrid":
        out["layers"] = L * _mamba_layer_flops(arch, B, S)
        n_shared = L // arch.shared_attn_every
        shared = (
            _qkvo_flops(arch, tokens)
            + _attn_flops(arch, B, S, compiled)
            + _mlp_flops(arch, tokens)
        )
        out["shared_attn"] = n_shared * shared
    else:
        per = _qkvo_flops(arch, tokens) + _attn_flops(arch, B, S, compiled)
        if arch.moe is not None:
            per += _moe_flops(arch, tokens, compiled)
        else:
            per += _mlp_flops(arch, tokens)
        out["layers"] = L * per
    if arch.frontend == "vlm":
        # patches extend the sequence
        pass  # patch tokens already included if caller adjusts S; keep simple
    out["head"] = _head_flops(arch, B, S)
    return out


def decode_flops(arch: ArchConfig, B: int, cache_len: int) -> Dict[str, float]:
    """One decode step for B sequences against a cache of cache_len."""
    out: Dict[str, float] = {}
    L = arch.n_layers
    H, hd = arch.n_heads, arch.resolved_head_dim
    if arch.family == "ssm" and arch.rwkv is not None:
        out["layers"] = L * _rwkv_layer_flops(arch, B, 1)
    elif arch.family == "hybrid":
        out["layers"] = L * _mamba_layer_flops(arch, B, 1)
        n_shared = L // arch.shared_attn_every
        attn = 2 * 2 * B * 1 * cache_len * H * hd
        out["shared_attn"] = n_shared * (
            _qkvo_flops(arch, B) + attn + _mlp_flops(arch, B)
        )
    else:
        kv = min(cache_len, arch.sliding_window) if arch.sliding_window else cache_len
        attn = 2 * 2 * B * 1 * kv * H * hd
        per = _qkvo_flops(arch, B) + attn
        if arch.moe is not None:
            per += _moe_flops(arch, B, True)
        else:
            per += _mlp_flops(arch, B)
        out["layers"] = L * per
    out["head"] = _head_flops(arch, B, 1)
    return out


# ---------------------------------------------------------------------------
# Bytes (HBM traffic estimates)
# ---------------------------------------------------------------------------
def param_bytes(n_params: float, dtype_bytes: int = BF16) -> float:
    return n_params * dtype_bytes


def train_bytes(arch: ArchConfig, n_params: float, B: int, S: int, microbatches: int) -> float:
    """Weights: read per microbatch in fwd + remat-fwd + bwd, grads written
    per microbatch (f32 accum read+write), optimizer reads/writes m, v,
    params.  Activations: ~12 d-sized streams per layer per token (reads +
    writes through the fused blocks) + attention score traffic."""
    pb = n_params * BF16
    weight_traffic = microbatches * 3 * pb  # fwd + remat + bwd reads
    grad_traffic = microbatches * 2 * n_params * F32 + 2 * n_params * F32
    opt_traffic = n_params * F32 * 4 + n_params * BF16 * 2  # m,v rw + param rw
    act = _activation_bytes(arch, B, S, training=True)
    return weight_traffic + grad_traffic + opt_traffic + act


def _activation_bytes(arch: ArchConfig, B: int, S: int, training: bool) -> float:
    d = arch.d_model
    L = arch.n_layers
    streams = 12 if not training else 30  # fwd vs fwd+remat+bwd
    act = L * B * S * d * BF16 * streams
    # attention scores (chunked: full S^2 traffic in f32 once each way)
    if arch.family not in ("ssm",) and arch.ssm is None:
        H = arch.n_heads
        kv_len = min(arch.sliding_window + ATTN_KV_BLOCK, S) if arch.sliding_window and S > arch.sliding_window else S
        act += L * B * S * kv_len * H * F32 * (2 if not training else 6)
    act += B * S * arch.padded_vocab_size * arch.n_codebooks * BF16 * (2 if training else 1)
    return act


def prefill_bytes(arch: ArchConfig, n_params: float, B: int, S: int) -> float:
    return n_params * BF16 + _activation_bytes(arch, B, S, training=False)


def decode_bytes(arch: ArchConfig, n_params: float, B: int, cache_len: int, cache_bytes: float) -> float:
    """Decode is memory-bound: weights once + the whole cache once."""
    act = arch.n_layers * B * arch.d_model * BF16 * 12
    return n_params * BF16 + cache_bytes + act


def moe_active_params(arch: ArchConfig, n_params_matmul: float) -> float:
    if arch.moe is None:
        return n_params_matmul
    return n_params_matmul * arch.active_param_count() / arch.param_count()


def cell_cost(
    arch: ArchConfig,
    shape: ShapeConfig,
    n_params_matmul: float,
    cache_bytes: float = 0.0,
    microbatches: int = 1,
) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        fwd = forward_flops(arch, B, S, compiled=True)
        fwd_total = sum(fwd.values())
        # bwd = 2x fwd, remat adds ~1x fwd recompute
        compiled = fwd_total * 4.0
        useful = 6.0 * moe_active_params(arch, n_params_matmul) * B * S + (
            3.0 * sum(forward_flops(arch, B, S, compiled=False).values())
            - 3.0 * 2 * B * S * moe_active_params(arch, n_params_matmul)
        )
        # useful = 6*N_active*D plus exact causal attention (3x fwd attention)
        useful = max(useful, 6.0 * moe_active_params(arch, n_params_matmul) * B * S)
        bytes_hbm = train_bytes(arch, n_params_matmul, B, S, microbatches)
        return CellCost(compiled, useful, bytes_hbm, fwd)
    if shape.kind == "prefill":
        fwd = forward_flops(arch, B, S, compiled=True)
        compiled = sum(fwd.values())
        useful = sum(forward_flops(arch, B, S, compiled=False).values())
        return CellCost(compiled, useful, prefill_bytes(arch, n_params_matmul, B, S), fwd)
    # decode
    fwd = decode_flops(arch, B, S)
    compiled = sum(fwd.values())
    useful = compiled  # decode computes no masked waste
    return CellCost(
        compiled, useful, decode_bytes(arch, n_params_matmul, B, S, cache_bytes), fwd
    )
