"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01]: GQA, no bias,
parallel attention+MLP block."""
from .base import ArchConfig, register

COMMAND_R_35B = register(
    ArchConfig(
        name="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        head_dim=128,
        attn_bias=False,
        parallel_block=True,
        mlp_act="silu_glu",
        norm="layernorm",
        tied_embeddings=True,
        rope_theta=10000.0,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
)
