"""Qwen1.5-110B [hf:Qwen family]: dense GQA with QKV bias."""
from .base import ArchConfig, register

QWEN15_110B = register(
    ArchConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        head_dim=128,
        attn_bias=True,  # QKV bias
        mlp_act="silu_glu",
        rope_theta=1000000.0,
        source="hf:Qwen/Qwen1.5-0.5B; hf",
    )
)
