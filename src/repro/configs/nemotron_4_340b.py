"""Nemotron-4-340B [arXiv:2402.16819]: dense GQA decoder, squared-ReLU MLP."""
from .base import ArchConfig, register

NEMOTRON_4_340B = register(
    ArchConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        head_dim=192,
        mlp_act="relu2",  # squared ReLU, non-gated
        norm="layernorm",
        rope_theta=10000.0,
        source="arXiv:2402.16819; unverified",
    )
)
