"""InternVL2-1B [arXiv:2404.16821]: InternViT frontend (STUB) + Qwen2-0.5B-class
decoder backbone (24L, d=896, 14H GQA kv=2)."""
from .base import ArchConfig, register

INTERNVL2_1B = register(
    ArchConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        head_dim=64,
        attn_bias=True,
        mlp_act="silu_glu",
        tied_embeddings=True,
        frontend="vlm",
        num_patches=256,
        rope_theta=1000000.0,
        source="arXiv:2404.16821; hf",
    )
)
