"""Assigned architecture configs (11 archs from the public pool) + shapes."""

import importlib

from .base import (
    ArchConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    all_archs,
    cells,
    get_arch,
)

_MODULES = [
    "nemotron_4_340b",
    "granite_3_8b",
    "command_r_35b",
    "qwen1_5_110b",
    "musicgen_large",
    "internvl2_1b",
    "rwkv6_3b",
    "zamba2_2_7b",
    "mixtral_8x7b",
    "phi3_5_moe_42b",
    "llama3_70b",
]

_loaded = False


def _load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
