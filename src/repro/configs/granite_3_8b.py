"""Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base family]: dense GQA."""
from .base import ArchConfig, register

GRANITE_3_8B = register(
    ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        head_dim=128,
        mlp_act="silu_glu",
        tied_embeddings=True,
        rope_theta=10000.0,
        source="hf:ibm-granite/granite-3.0-2b-base; hf",
    )
)
