"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens.

Modality frontend is a STUB: input_specs() provides precomputed frame
embeddings (the sum of the 4 codebook embeddings after the delay pattern);
the backbone predicts all 4 codebooks per frame (mean CE across codebooks).
"""
from .base import ArchConfig, register

MUSICGEN_LARGE = register(
    ArchConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        head_dim=64,
        mlp_act="gelu",
        norm="layernorm",
        frontend="audio",
        n_codebooks=4,
        source="arXiv:2306.05284; hf",
    )
)
