"""Architecture configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; reduced
("smoke") variants are derived with :meth:`ArchConfig.reduced`.  Configs are
registered by id and selectable via ``--arch`` in the launchers.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    # d_ff of each expert (the ArchConfig.d_ff refers to the per-expert width
    # for MoE archs, matching the public configs).


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128  # SSD chunk length
    n_groups: int = 1  # B/C projection groups (Mamba2)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64  # low-rank size of the data-dependent decay (Finch)
    gate_lora: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    # attention
    attn_bias: bool = False  # qkv bias (Qwen-style)
    sliding_window: Optional[int] = None  # SWA width (Mixtral)
    rope_theta: float = 10000.0
    # block structure
    mlp_act: str = "silu_glu"  # silu_glu | gelu_glu | relu2 | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    parallel_block: bool = False  # Command-R style parallel attn+MLP
    tied_embeddings: bool = False
    # mixtures / ssm / rwkv
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    shared_attn_every: int = 0  # Zamba2: shared attention block interval
    # modality frontends (STUB: input_specs provides precomputed embeddings)
    frontend: str = "none"  # none | audio | vlm
    n_codebooks: int = 1  # MusicGen EnCodec codebooks
    num_patches: int = 256  # VLM stub: visual tokens prepended
    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # notes for DESIGN.md provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding / lm_head can
        shard over the tensor-parallel axis (standard practice; the pad ids
        are never emitted by the tokenizer / data pipeline)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence handling: SSM / hybrid / sliding-window."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qkv = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        if self.family == "ssm" and self.rwkv is not None:
            per_layer = 4 * d * d + 2 * d * ff  # r,k,v,o + channel mix
        elif self.ssm is not None and self.family in ("ssm", "hybrid"):
            d_in = self.ssm.expand * d
            # in_proj (x, z) + dt/B/C projections + out_proj
            per_layer = 2 * d * d_in + d * 2 * self.ssm.n_groups * self.ssm.state_dim + d_in * d
        else:
            per_layer = qkv
        glu = 3 if self.mlp_act.endswith("_glu") else 2
        if self.moe is not None:
            per_layer += self.moe.num_experts * glu * d * ff + d * self.moe.num_experts
        elif self.family == "ssm" and self.rwkv is not None:
            pass  # channel mix already counted
        elif self.ssm is None:
            per_layer += glu * d * ff
        if self.shared_attn_every:
            shared = qkv + 3 * d * ff
        else:
            shared = 0
        embed = V * d * (1 if self.tied_embeddings else 2) * self.n_codebooks
        return self.n_layers * per_layer + shared + embed

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        glu = 3 if self.mlp_act.endswith("_glu") else 2
        inactive = (self.moe.num_experts - self.moe.top_k) * glu * d * ff
        return self.param_count() - self.n_layers * inactive

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if not self.shared_attn_every else 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=128,
            head_dim=16,
            sliding_window=8 if self.sliding_window else None,
            num_patches=8,
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, num_experts=4, top_k=2)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, state_dim=8, head_dim=16, chunk=8)
        if self.rwkv is not None:
            kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8, gate_lora=8)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        return replace(self, name=self.name + "-smoke", **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    from . import _load_all  # late import to populate registry

    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    from . import _load_all

    _load_all()
    return dict(_REGISTRY)


def cells(arch: ArchConfig) -> Tuple[str, ...]:
    """The shape cells that apply to an architecture (skips noted in
    DESIGN.md §Arch-applicability: long_500k needs sub-quadratic attention)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.supports_long_context:
        out.append("long_500k")
    return tuple(out)
