"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone + shared attention block.

54 Mamba2 layers with a single shared transformer (attention+MLP) block
applied every 6 layers (the public model alternates two shared blocks with
LoRA adapters; we use one shared block — noted in DESIGN.md
§Arch-applicability)."""
from .base import ArchConfig, SSMConfig, register

ZAMBA2_2_7B = register(
    ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        head_dim=80,
        mlp_act="gelu_glu",
        ssm=SSMConfig(state_dim=64, expand=2, head_dim=64, chunk=128, n_groups=1),
        shared_attn_every=6,
        source="arXiv:2411.15242; hf",
    )
)
