"""RWKV6-3B "Finch" [arXiv:2404.05892]: attention-free, data-dependent decay."""
from .base import ArchConfig, RWKVConfig, register

RWKV6_3B = register(
    ArchConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,  # d_model / rwkv.head_dim
        n_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        head_dim=64,
        mlp_act="relu2",  # RWKV channel-mix uses squared ReLU
        norm="layernorm",
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=64),
        source="arXiv:2404.05892; hf",
    )
)
