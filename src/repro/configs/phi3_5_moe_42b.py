"""Phi-3.5-MoE 42B (A6.6B) [hf:microsoft/Phi-3.5-MoE-instruct]: 16-expert top-2."""
from .base import ArchConfig, MoEConfig, register

PHI35_MOE_42B = register(
    ArchConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        head_dim=128,
        mlp_act="silu_glu",
        moe=MoEConfig(num_experts=16, top_k=2),
        source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    )
)
