"""Mixtral-8x7B [arXiv:2401.04088]: 8-expert top-2 MoE with sliding-window attn."""
from .base import ArchConfig, MoEConfig, register

MIXTRAL_8X7B = register(
    ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        head_dim=128,
        sliding_window=4096,
        mlp_act="silu_glu",
        moe=MoEConfig(num_experts=8, top_k=2),
        rope_theta=1000000.0,
        source="arXiv:2401.04088; hf",
    )
)
