"""Llama-3-70B [arXiv:2407.21783]: dense GQA workhorse (the 11th config).

Added so the fleet planner's per-config table covers the canonical dense
serving target alongside the MoE / SSM / hybrid families.
"""
from .base import ArchConfig, register

LLAMA3_70B = register(
    ArchConfig(
        name="llama3-70b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        head_dim=128,
        mlp_act="silu_glu",
        rope_theta=500000.0,
        source="arXiv:2407.21783; hf:meta-llama/Meta-Llama-3-70B",
    )
)
