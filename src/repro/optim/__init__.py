from .adamw import AdamWConfig, AdamWState, init, update, schedule, global_norm
from . import compression
