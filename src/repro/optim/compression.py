"""Gradient compression for cross-pod data parallelism.

On the multi-pod mesh the "pod" axis rides the data-center interconnect
(~4x slower than ICI), so the cross-pod gradient all-reduce is the slowest
collective of the step.  Two standard compressors, both with error feedback
(the residual is re-added next step so compression error doesn't bias the
optimizer — Seide et al. / Karimireddy et al.):

* ``int8``  — per-tensor symmetric quantization: 4x less DCI traffic;
* ``topk``  — magnitude top-k sparsification (k as a fraction).

Usage pattern (see launch/train.py): gradients are all-reduced over the ICI
axes at full precision; the pod-axis reduction uses ``compress`` ->
``jax.lax.psum`` of the dequantized values inside shard_map (the compression
happens before crossing the slow link).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressionState(NamedTuple):
    residual: PyTree  # error-feedback accumulator (f32, like grads)


def init_state(grads_like: PyTree) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


# ---------------------------------------------------------------------------
# int8 symmetric quantization
# ---------------------------------------------------------------------------
def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------
def sparsify_topk(x: jax.Array, frac: float) -> jax.Array:
    """Keep the top-|frac| fraction by magnitude (as a dense masked tensor —
    the wire format would send (indices, values); the mask is what matters
    for the error-feedback math and the traffic model)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compress_with_feedback(
    grads: PyTree,
    state: CompressionState,
    method: str = "int8",
    topk_frac: float = 0.01,
) -> Tuple[PyTree, CompressionState, PyTree]:
    """Returns (compressed-then-decompressed grads, new state, wire pytree).

    The caller all-reduces the returned grads across the slow axis; the
    error (original - transmitted) is fed back into the next step.
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if method == "int8":
            q, scale = quantize_int8(gf)
            sent = dequantize_int8(q, scale)
            wire = (q, scale)
        elif method == "topk":
            sent = sparsify_topk(gf, topk_frac)
            wire = sent
        elif method == "none":
            sent = gf
            wire = gf
        else:
            raise ValueError(f"unknown compression method {method}")
        return sent, gf - sent, wire

    out = jax.tree.map(one, grads, state.residual)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    sent = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    resid = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    wire = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return sent, CompressionState(residual=resid), wire


def wire_bytes(wire: PyTree) -> int:
    """Traffic of the compressed representation (for the collective model)."""
    total = 0
    for leaf in jax.tree.leaves(wire):
        total += leaf.size * leaf.dtype.itemsize
    return total
