"""Sharded AdamW with warmup-cosine schedule and global-norm clipping.

Functional optimizer (init/update pair over pytrees) so the optimizer state
inherits the parameter sharding specs 1:1 (ZeRO-style partitioning falls out
of the pjit in_shardings — no separate optimizer-sharding machinery).
Moments are f32 regardless of the bf16 parameter dtype (mixed-precision
master moments; master weights are optional and off by default to match
common large-model recipes that keep bf16 params + f32 moments).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: Optional[float] = 1.0


class AdamWState(NamedTuple):
    step: jax.Array  # i32 scalar
    m: PyTree  # f32, like params
    v: PyTree  # f32, like params


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1 - floor) * cos)


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / 1D params."""
    last = str(path[-1]) if path else ""
    return "scale" not in last and "bias" not in last


def update(
    cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree
) -> Tuple[PyTree, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path) and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v), params, grads, state.m, state.v
    )
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
