"""Train / serve step builders: microbatched gradient accumulation, AdamW
update, and the decode step — the functions the launchers jit/lower.

``make_train_step`` returns a pure ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` function:

* the global batch is split into ``microbatches`` chunks scanned with
  gradient accumulation (the activation-memory knob for the big archs);
* remat policy comes from the model (scan-over-layers checkpointing);
* the AdamW update runs in f32 with global-norm clipping.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model
from repro.optim import adamw

PyTree = Any


def _split_microbatch(batch: Dict[str, jax.Array], n: int, i: jax.Array):
    def slice_one(x):
        mb = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

    return jax.tree.map(slice_one, batch)


def make_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    microbatches: int = 1,
    grad_shardings: Optional[PyTree] = None,
    unroll_loop: bool = False,
) -> Callable:
    """``grad_shardings``: optional pytree of Shardings (like params) —
    constrains gradients and the accumulator so ZeRO stays sharded under
    pjit (otherwise XLA may all-reduce full f32 gradients).
    ``unroll_loop`` unrolls the gradient-accumulation scan (dry-run cost
    calibration: XLA counts while bodies once)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain(grads)
        else:
            def acc_body(carry, i):
                g_acc, l_acc = carry
                mb = _split_microbatch(batch, microbatches, i)
                (loss, _), grads = grad_fn(params, mb)
                grads = constrain(grads)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (constrain(g_acc), l_acc + loss), None

            g0 = constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (g_sum, l_sum), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), jnp.arange(microbatches),
                unroll=True if unroll_loop else 1,
            )
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            metrics = {}
        new_params, new_opt, opt_metrics = adamw.update(opt_cfg, grads, opt_state, params)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_opt, out_metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}

    return eval_step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def make_prefill_step(model: Model) -> Callable:
    """Prefill: forward over the prompt; returns last-position logits.
    (KV-cache population for the transformer families reuses decode_step in
    a scan for exactness; at serving scale the flash kernel path emits the
    cache directly — dry-runs lower `forward` which has identical cost.)"""

    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits[:, -1]

    return prefill


def make_decode_step(model: Model) -> Callable:
    def decode(params, cache, batch, position):
        logits, new_cache = model.decode_step(params, cache, batch, position)
        next_token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits[:, -1], axis=-1)
        return next_token, new_cache

    return decode
