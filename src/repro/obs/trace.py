"""Nestable span tracer with Chrome trace-event JSON export.

One process-wide :class:`Tracer` (:data:`TRACER`) records *spans* — named
wall-clock intervals with key/value annotations and additive counters —
around the stack's hot boundaries: scheduler event processing, placement
search, netsim draining, backend dispatch, planner candidate pricing.
Spans nest per thread (the exporter reconstructs the hierarchy from
interval containment), and the recorded stream exports as Chrome
trace-event JSON (``"X"`` complete events), directly loadable in
Perfetto / ``chrome://tracing``.

Tracing is **globally off by default** and the disabled path is near
zero: ``TRACER.span(...)`` returns a shared no-op context manager after
one attribute check, and the hot call sites additionally guard on
``TRACER.enabled`` so no argument dict is even built.  Enabling tracing
never perturbs results — spans only *measure*; the scheduler event log,
netsim makespans, and planner tables are bit-identical either way
(pinned in ``tests/test_obs.py``, overhead gated in ``BENCH_obs.json``).

>>> TRACER.enabled
False
>>> with TRACER.span("demo"):       # no-op: tracing is off
...     pass
>>> TRACER.events()
[]
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Timer", "Tracer", "TRACER"]


class Span:
    """One live span: a named interval opened by :meth:`Tracer.span`.

    Use as a context manager; :meth:`annotate` attaches key/value pairs
    and :meth:`incr` accumulates additive counters — both land in the
    exported event's ``args``."""

    __slots__ = ("name", "args", "tid", "_tracer", "_t0", "duration")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.tid = threading.get_ident()
        self._t0 = 0
        self.duration = 0.0  # seconds, set at exit

    def annotate(self, **kv: Any) -> "Span":
        """Attach key/value annotations to the span."""
        self.args.update(kv)
        return self

    def incr(self, key: str, n: float = 1) -> "Span":
        """Accumulate an additive counter in the span's args."""
        self.args[key] = self.args.get(key, 0) + n
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter_ns()
        self.duration = (t1 - self._t0) * 1e-9
        self._tracer._record(self, self._t0, t1)
        return False


class _NoopSpan:
    """Shared disabled-path span: every method is a cheap no-op."""

    __slots__ = ()
    name = ""
    args: Dict[str, Any] = {}
    duration = 0.0

    def annotate(self, **kv: Any) -> "_NoopSpan":
        return self

    def incr(self, key: str, n: float = 1) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NOOP = _NoopSpan()


class Timer:
    """Always-measuring wall-clock context manager (``obs.timer``).

    Replaces ad-hoc ``time.perf_counter()`` pairs: ``elapsed`` is always
    populated (seconds), and when tracing is enabled the interval is
    *also* recorded as a span — so driver wall-clock numbers land in the
    same trace stream as the engine spans."""

    __slots__ = ("name", "args", "elapsed", "_tracer", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.elapsed = 0.0
        self._t0 = 0

    def annotate(self, **kv: Any) -> "Timer":
        """Attach key/value annotations (recorded when tracing is on)."""
        self.args.update(kv)
        return self

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter_ns()
        self.elapsed = (t1 - self._t0) * 1e-9
        if self._tracer.enabled:
            span = Span(self._tracer, self.name, self.args)
            span.duration = self.elapsed
            self._tracer._record(span, self._t0, t1)
        return False


class Tracer:
    """Thread-safe span recorder exporting Chrome trace-event JSON.

    ``enabled`` is a plain attribute — the single check the disabled
    path pays.  Finished spans append under a lock as ``"X"`` (complete)
    trace events with microsecond ``ts``/``dur`` relative to the
    tracer's epoch; per-thread ``tid`` keeps nesting reconstructible.
    """

    def __init__(self) -> None:
        self.enabled = False
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._epoch = time.perf_counter_ns()

    # -- control ------------------------------------------------------------
    def enable(self, clear: bool = False) -> None:
        """Turn tracing on (optionally clearing recorded events first)."""
        if clear:
            self.clear()
        self.enabled = True

    def disable(self) -> None:
        """Turn tracing off; recorded events are kept until :meth:`clear`."""
        self.enabled = False

    def clear(self) -> None:
        """Drop all recorded events and reset the time epoch."""
        with self._lock:
            self._events = []
            self._epoch = time.perf_counter_ns()

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **args: Any):
        """Open a span (context manager).  Disabled: returns a shared
        no-op after one attribute check — the near-zero path gated by
        ``BENCH_obs.json``."""
        if not self.enabled:
            return _NOOP
        return Span(self, name, args)

    def timer(self, name: str, **args: Any) -> Timer:
        """An always-measuring :class:`Timer` (span recorded only when
        tracing is enabled)."""
        return Timer(self, name, args)

    def _record(self, span: Span, t0_ns: int, t1_ns: int) -> None:
        event = {
            "name": span.name,
            "ph": "X",
            "ts": (t0_ns - self._epoch) * 1e-3,  # microseconds
            "dur": (t1_ns - t0_ns) * 1e-3,
            "pid": os.getpid(),
            "tid": span.tid,
        }
        if span.args:
            event["args"] = dict(span.args)
        with self._lock:
            self._events.append(event)

    # -- export -------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of the recorded trace events (copies)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (``traceEvents`` sorted by
        start time, parents before their children)."""
        events = self.events()
        events.sort(key=lambda e: (e["tid"], e["ts"], -e["dur"]))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Return the Chrome trace object, writing it to ``path`` (JSON)
        when given."""
        trace = self.chrome_trace()
        if path is not None:
            with open(path, "w") as fh:
                json.dump(trace, fh, indent=1)
        return trace


#: The process-wide tracer every instrumented module records into.
TRACER = Tracer()
