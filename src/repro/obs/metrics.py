"""Metrics registry: counters, gauges, histograms with labeled series.

A :class:`MetricsRegistry` holds named instruments, each a family of
*labeled series* (``name{job=3}`` style), and snapshots to plain JSON.
The scheduler's metrics are not sampled inline — they are **derived from
the event log** by :func:`scheduler_metrics`, so replaying a log through
a fresh service (:func:`repro.network.scheduler.replay_events`)
reproduces every metric exactly, bit-for-bit (pinned in
``tests/test_obs.py``).

>>> reg = MetricsRegistry()
>>> reg.counter("events", kind="arrival").incr(3)
>>> reg.gauge("depth").set(2.0)
>>> h = reg.histogram("wait")
>>> h.observe(0.5); h.observe(12.0)
>>> snap = reg.snapshot()
>>> snap["counters"]["events{kind=arrival}"]
3
>>> snap["histograms"]["wait"]["count"]
2
"""

from __future__ import annotations

import json
import math
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "scheduler_metrics",
]

#: Default histogram bucket upper bounds (log-spaced decades with 1-3
#: subdivision; +inf is implicit).
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    m * 10.0 ** e for e in range(-3, 5) for m in (1.0, 3.0)
)


def _series_key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing count (one labeled series)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def incr(self, n: float = 1) -> None:
        """Add ``n`` (must be >= 0) to the counter."""
        if n < 0:
            raise ValueError("counters only increase; use a gauge")
        self.value += n


class Gauge:
    """Point-in-time value (one labeled series)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        """Set the gauge to ``v`` (stored as given — exactness matters
        for the per-job efficiency gauges)."""
        self.value = v


class Histogram:
    """Cumulative-bucket histogram with exact count/sum/min/max.

    ``buckets`` are upper bounds (``le``); an implicit +inf bucket
    catches the overflow.  ``observe`` is exact on the summary stats —
    only the distribution is quantised."""

    __slots__ = ("buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        """Record one observation."""
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        lo, hi = 0, len(self.buckets)
        while lo < hi:  # first bucket with bound >= v
            mid = (lo + hi) // 2
            if self.buckets[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }
        nonzero = {}
        for bound, c in zip(self.buckets + (math.inf,), self.counts):
            if c:
                nonzero[f"{bound:g}"] = c
        out["buckets"] = nonzero
        return out


class MetricsRegistry:
    """Thread-safe registry of labeled counter/gauge/histogram series.

    Instruments are created on first touch; the same ``(name, labels)``
    pair always returns the same series.  :meth:`snapshot` renders the
    whole registry to a plain JSON-able dict."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, **labels: Any) -> Counter:
        """The counter series for ``(name, labels)`` (created on first use)."""
        key = _series_key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        """The gauge series for ``(name, labels)`` (created on first use)."""
        key = _series_key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge()
        return g

    def histogram(
        self, name: str, buckets: Sequence[float] = DEFAULT_BUCKETS, **labels: Any
    ) -> Histogram:
        """The histogram series for ``(name, labels)`` (created on first
        use; ``buckets`` only applies at creation)."""
        key = _series_key(name, labels)
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = Histogram(buckets)
        return h

    def clear(self) -> None:
        """Drop every series."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able view: ``{"counters": .., "gauges": .., "histograms": ..}``."""
        with self._lock:
            return {
                "counters": {k: c.value for k, c in sorted(self._counters.items())},
                "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
                "histograms": {
                    k: h.to_dict() for k, h in sorted(self._histograms.items())
                },
            }

    def export(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Snapshot, optionally written to ``path`` as JSON."""
        snap = self.snapshot()
        if path is not None:
            with open(path, "w") as fh:
                json.dump(snap, fh, indent=1)
        return snap


#: Process-wide default registry (backend jit/padding counters land here).
REGISTRY = MetricsRegistry()


# ---------------------------------------------------------------------------
# Scheduler metrics — derived from the event log, never sampled inline.
# ---------------------------------------------------------------------------
def scheduler_metrics(service, registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Derive a :class:`SchedulerService`'s metrics from its event log.

    Populates (into ``registry``, default a fresh one):

    * ``scheduler.events{kind=..}`` counters, plus ``scheduler.preemptions``,
      ``scheduler.backpressure_sheds``, ``scheduler.rejections``;
    * ``scheduler.queue_depth`` histogram (sampled at every log record)
      and ``scheduler.queue_depth_max`` gauge — reconstructed by walking
      arrivals/starts/rejects in log order;
    * ``scheduler.wait_time`` / ``scheduler.turnaround`` histograms
      (start - arrival, completion - first arrival per job);
    * ``scheduler.utilization`` gauge — busy cell-time over total
      cell-time across the log horizon;
    * per-job ``scheduler.job.bisection_efficiency{job=..}`` and
      ``scheduler.job.simulated_slowdown{job=..}`` gauges, **exactly**
      the values on the service's :class:`ScheduledJob` records (so the
      snapshot matches ``service.result()`` bit-for-bit).

    Everything is a pure function of the log plus the scheduled-job
    table, both of which replay deterministically — so metrics from a
    replayed service equal the original's snapshot exactly.
    """
    reg = registry if registry is not None else MetricsRegistry()
    log = service.log

    depth = 0
    depth_max = 0
    waiting_since: Dict[int, float] = {}
    first_arrival: Dict[int, float] = {}
    depth_hist = reg.histogram("scheduler.queue_depth")
    wait_hist = reg.histogram("scheduler.wait_time")
    turn_hist = reg.histogram("scheduler.turnaround")
    for event in log:
        reg.counter("scheduler.events", kind=event.kind).incr()
        if event.kind == "arrival":
            waiting_since[event.job_id] = event.time
            first_arrival.setdefault(event.job_id, event.time)
            depth += 1
        elif event.kind == "start":
            t_arr = waiting_since.pop(event.job_id, event.time)
            wait_hist.observe(event.time - t_arr)
            depth -= 1
        elif event.kind == "reject":
            if event.job_id in waiting_since:
                del waiting_since[event.job_id]
                depth -= 1
            reg.counter("scheduler.rejections", reason=event.reason or "").incr()
            if event.reason == "backpressure":
                reg.counter("scheduler.backpressure_sheds").incr()
        elif event.kind == "complete":
            t0 = first_arrival.get(event.job_id)
            if t0 is not None:
                turn_hist.observe(event.time - t0)
        elif event.kind == "preempt":
            reg.counter("scheduler.preemptions", reason=event.reason or "").incr()
        if depth > depth_max:
            depth_max = depth
        depth_hist.observe(depth)
    reg.gauge("scheduler.queue_depth").set(float(depth))
    reg.gauge("scheduler.queue_depth_max").set(float(depth_max))

    # Utilization: busy cell-time over the log horizon (committed segments
    # are clipped to the horizon; an empty log reads 0).
    horizon = log[-1].time if log else 0.0
    total_cells = 1
    for a in service.machine.dims:
        total_cells *= int(a)
    busy = 0.0
    import numpy as _np

    for job in service.scheduled:
        units = int(_np.prod(job.placement.oriented))
        busy += max(0.0, min(job.end, horizon) - job.start) * units
    denom = total_cells * horizon
    reg.gauge("scheduler.utilization").set(busy / denom if denom > 0 else 0.0)

    for job in service.scheduled:
        jid = job.request.job_id
        reg.gauge("scheduler.job.bisection_efficiency", job=jid).set(
            job.bisection_efficiency
        )
        reg.gauge("scheduler.job.simulated_slowdown", job=jid).set(
            job.simulated_slowdown
        )
    return reg
