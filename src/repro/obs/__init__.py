"""repro.obs — zero-dependency telemetry: tracing, metrics, contention.

Three pillars, wired through the whole stack (see DESIGN.md "Telemetry
and contention attribution"):

* :mod:`repro.obs.trace` — a nestable, thread-safe span tracer, globally
  **off by default** with a near-zero disabled path (gated <= 2%
  overhead in ``BENCH_obs.json``), exporting Chrome trace-event JSON.
  Instrumented boundaries: scheduler event processing
  (``scheduler.step`` / ``scheduler.place``), placement search
  (``placement.search``), netsim draining (``netsim.drain``), backend
  dispatch (``backend.*`` with jit recompile / padding-bucket counters
  and a compile-vs-execute split), planner candidate pricing
  (``planner.price``), and the launch drivers' wall-clock timers.
* :mod:`repro.obs.metrics` — a registry of counters / gauges /
  histograms with labeled series and JSON snapshot export;
  :func:`scheduler_metrics` derives the scheduler's queue-depth /
  wait / turnaround / utilization / per-job efficiency metrics from the
  event log, so replaying a log reproduces the metrics exactly.
* :mod:`repro.obs.contention` — per-link load attribution by owning job
  (self vs. cross traffic), hotspot flagging, and the
  **avoidable-contention** gauge: measured load of the granted geometry
  vs. the Theorem 3.1-certified optimal from ``advise_partition`` — the
  paper's headline quantity as a continuously-observable metric.

Quickstart::

    from repro import obs
    obs.enable_tracing()
    ...  # run scheduler / netsim / planner work
    obs.export_chrome_trace("trace.json")   # open in Perfetto
    obs.metrics_registry().export("metrics.json")
    report = obs.attribute_contention(machine)
    print(obs.render_dashboard(report))

>>> tracing_enabled()
False
>>> with trace("noop"):
...     pass
>>> export_chrome_trace()["traceEvents"]
[]
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from .trace import TRACER, Span, Timer, Tracer
from .metrics import (
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    scheduler_metrics,
)
from .contention import (
    ContentionReport,
    HotspotLink,
    JobContention,
    attribute_contention,
    attribute_traffic,
    render_dashboard,
)

__all__ = [
    "TRACER",
    "REGISTRY",
    "Span",
    "Timer",
    "Tracer",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ContentionReport",
    "HotspotLink",
    "JobContention",
    "attribute_contention",
    "attribute_traffic",
    "render_dashboard",
    "scheduler_metrics",
    "enable_tracing",
    "disable_tracing",
    "tracing_enabled",
    "trace",
    "timer",
    "export_chrome_trace",
    "clear_telemetry",
    "metrics_registry",
    "metrics_snapshot",
]


def enable_tracing(clear: bool = False) -> None:
    """Turn the process-wide tracer on (``clear=True`` drops prior events)."""
    TRACER.enable(clear=clear)


def disable_tracing() -> None:
    """Turn the process-wide tracer off (events are kept)."""
    TRACER.disable()


def tracing_enabled() -> bool:
    """Whether the process-wide tracer is recording."""
    return TRACER.enabled


def trace(name: str, **args: Any):
    """Open a span on the process-wide tracer (no-op while disabled)."""
    return TRACER.span(name, **args)


def timer(name: str, **args: Any) -> Timer:
    """An always-measuring :class:`Timer` on the process-wide tracer."""
    return TRACER.timer(name, **args)


def export_chrome_trace(path: Optional[str] = None) -> Dict[str, Any]:
    """The process-wide tracer's Chrome trace object (written to ``path``
    when given)."""
    return TRACER.export(path)


def metrics_registry() -> MetricsRegistry:
    """The process-wide default metrics registry."""
    return REGISTRY


def metrics_snapshot() -> Dict[str, Any]:
    """JSON-able snapshot of the process-wide metrics registry."""
    return REGISTRY.snapshot()


def clear_telemetry() -> None:
    """Drop all recorded trace events and metrics series."""
    TRACER.clear()
    REGISTRY.clear()
