"""Per-link contention attribution and the avoidable-contention gauge.

The paper's argument is that contention is *avoidable*: a partition's
communication time is pinned by its bisection, and the isoperimetry
engine certifies how far any granted geometry sits above the best
achievable one.  This module turns that into a continuously-observable
report over a live :class:`~repro.network.allocation.MachineState` (or
any explicit per-job traffic decomposition):

* **per-link attribution** — each live job's all-to-all load field,
  split into *self* traffic (links whose both endpoints are the job's
  own cells) and *cross* traffic (links it loads through foreign
  territory — the spill corridors of
  :func:`repro.network.placement.is_spilling`);
* **hotspot links** — the most loaded links of the summed background,
  each broken down by owning job;
* **avoidable contention** — per partition, the measured max link load
  of its granted geometry against the pairing load of the
  certified-optimal geometry from
  :func:`repro.network.isoperimetry.advise_partition` (whose ``bound``
  is the Theorem 3.1 floor): ``avoidable_ratio`` is the paper's
  headline current/optimal time ratio (1.0 = nothing avoidable),
  ``avoidable_excess`` the same minus one.

Rendered as a text dashboard (:func:`render_dashboard`, see
``examples/telemetry_dashboard.py``) and machine-readable JSON
(:meth:`ContentionReport.to_dict`).

>>> from repro.network.allocation import MachineState
>>> m = MachineState((4, 4, 4))
>>> _ = m.allocate(0, (2, 2, 2))
>>> rep = attribute_contention(m)
>>> [j.job_id for j in rep.jobs], rep.jobs[0].avoidable_ratio
([0], 1.0)
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "HotspotLink",
    "JobContention",
    "ContentionReport",
    "attribute_contention",
    "attribute_traffic",
    "render_dashboard",
]


@dataclass(frozen=True)
class JobContention:
    """Attribution record for one live partition."""

    job_id: int
    units: int
    geometry: Tuple[int, ...]
    oriented: Tuple[int, ...]
    offset: Tuple[int, ...]
    self_load: float  # job traffic on links internal to its own cells
    cross_load: float  # job traffic routed through foreign territory
    max_link_load: float  # measured peak of the job's own field
    pairing_load: float  # pairing-benchmark peak of the granted geometry
    optimal_geometry: Optional[Tuple[int, ...]]  # advisor's certified best
    optimal_max_load: float  # pairing peak of the optimal geometry
    bound: float  # Theorem 3.1 floor on the optimal bisection cut
    avoidable_ratio: float  # pairing time current/optimal (>= 1.0)
    certified: bool  # optimum pinned analytically by the bound

    @property
    def avoidable_excess(self) -> float:
        """Avoidable fraction of the job's communication time: 0.0 when
        the granted geometry is isoperimetrically optimal, ~1.0 when the
        paper's worst geometry doubles it."""
        return self.avoidable_ratio - 1.0


@dataclass(frozen=True)
class HotspotLink:
    """One heavily loaded directed link with its per-job load shares."""

    dim: int
    direction: int
    cell: Tuple[int, ...]
    load: float
    shares: Dict[int, float]  # job_id -> load contribution


@dataclass(frozen=True)
class ContentionReport:
    """Machine-wide contention attribution snapshot."""

    dims: Tuple[int, ...]
    jobs: Tuple[JobContention, ...]
    hotspots: Tuple[HotspotLink, ...]
    total_load: float  # summed background volume over all links
    max_link_load: float  # peak of the summed background
    cross_load: float = 0.0  # summed cross traffic over all jobs

    def to_dict(self) -> Dict[str, Any]:
        """Machine-readable JSON form of the report."""
        return {
            "dims": list(self.dims),
            "total_load": self.total_load,
            "max_link_load": self.max_link_load,
            "cross_load": self.cross_load,
            "jobs": [
                {
                    "job_id": j.job_id,
                    "units": j.units,
                    "geometry": list(j.geometry),
                    "oriented": list(j.oriented),
                    "offset": list(j.offset),
                    "self_load": j.self_load,
                    "cross_load": j.cross_load,
                    "max_link_load": j.max_link_load,
                    "pairing_load": j.pairing_load,
                    "optimal_geometry": (
                        None
                        if j.optimal_geometry is None
                        else list(j.optimal_geometry)
                    ),
                    "optimal_max_load": j.optimal_max_load,
                    "theorem31_bound": j.bound,
                    "avoidable_ratio": j.avoidable_ratio,
                    "avoidable_excess": j.avoidable_excess,
                    "certified": j.certified,
                }
                for j in self.jobs
            ],
            "hotspots": [
                {
                    "dim": h.dim,
                    "direction": h.direction,
                    "cell": list(h.cell),
                    "load": h.load,
                    "shares": {str(k): v for k, v in sorted(h.shares.items())},
                }
                for h in self.hotspots
            ],
        }

    def to_json(self, path: Optional[str] = None) -> str:
        """Serialise :meth:`to_dict`; also write to ``path`` when given."""
        text = json.dumps(self.to_dict(), indent=1)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text)
        return text


def _own_link_mask(
    dims: Tuple[int, ...], oriented: Sequence[int], offset: Sequence[int]
) -> np.ndarray:
    """(D, 2, *dims) bool: links whose both endpoints are the job's cells."""
    from repro.network.placement import placement_cells

    cells = np.zeros(dims, dtype=bool)
    cells[placement_cells(dims, tuple(oriented), tuple(offset))] = True
    D = len(dims)
    mask = np.zeros((D, 2) + dims, dtype=bool)
    for k in range(D):
        fwd = cells & np.roll(cells, -1, axis=k)  # link cell -> cell+1
        mask[k, 0] = fwd
        mask[k, 1] = np.roll(fwd, 1, axis=k)  # link cell -> cell-1
    return mask


def _advise(
    dims_or_fabric,
    units: int,
    geometry: Tuple[int, ...],
    unit_node_dims: Optional[Sequence[int]],
) -> Tuple[Optional[Tuple[int, ...]], float, float, float, float, bool]:
    """(optimal_geometry, pairing_load, optimal_load, bound, ratio,
    certified) for one partition, via the isoperimetry advisor.  Accepts
    torus dims or a :class:`~repro.network.fabric.HyperXFabric`, whose
    contention benchmark is the box-internal all-to-all (pairing never
    contends across diameter-1 dimensions)."""
    from repro.network.fabric import HyperXFabric
    from repro.network.isoperimetry import advise_partition, scaled_node_dims
    from repro.network.routing import (
        hyperx_all_to_all_max_load,
        predict_pairing_time,
    )

    try:
        advice = advise_partition(
            dims_or_fabric, units, geometry, unit_node_dims=unit_node_dims
        )
    except ValueError:
        return None, 0.0, 0.0, 0.0, 1.0, False
    if isinstance(dims_or_fabric, HyperXFabric):
        cur_load = hyperx_all_to_all_max_load(dims_or_fabric.sub_fabric(geometry))
        opt_load = hyperx_all_to_all_max_load(
            dims_or_fabric.sub_fabric(advice.optimal_geometry)
        )
    else:
        cur_nodes = scaled_node_dims(geometry, unit_node_dims)
        opt_nodes = scaled_node_dims(advice.optimal_geometry, unit_node_dims)
        cur_load = predict_pairing_time(cur_nodes, 1.0, 1.0).max_link_load
        opt_load = predict_pairing_time(opt_nodes, 1.0, 1.0).max_link_load
    return (
        tuple(advice.optimal_geometry),
        float(cur_load),
        float(opt_load),
        float(advice.bound),
        float(advice.predicted_speedup),
        bool(advice.certified),
    )


def _attribute_hyperx(
    fabric,
    loads_by_job: Dict[int, np.ndarray],
    placements: Dict[int, Any],
    *,
    top_hotspots: int = 5,
) -> ContentionReport:
    """HyperX body of :func:`attribute_traffic`: flat per-slot load
    vectors in the dense link layout of ``fabric.links()``.  The hotspot
    records reuse :class:`HotspotLink` with HyperX semantics —
    ``direction`` is the destination *coordinate* of the clique link, not
    a torus +/- direction."""
    from repro.network.placement import placement_cells

    dims = fabric.dims
    n = int(np.prod(dims))
    table = fabric.links()
    n_slots = table.n_slots
    total = np.zeros(n_slots, dtype=np.float64)
    jobs: List[JobContention] = []
    cross_total = 0.0
    for jid in sorted(loads_by_job):
        loads = np.asarray(loads_by_job[jid], dtype=np.float64)
        if loads.shape != (n_slots,):
            raise ValueError(
                f"job {jid} loads must have shape ({n_slots},) for H{dims}; "
                f"got {loads.shape}"
            )
        total += loads
        p = placements.get(jid)
        if p is not None:
            oriented = tuple(int(w) for w in p.oriented)
            offset = tuple(int(o) for o in p.offset)
            geometry = tuple(int(g) for g in p.geometry)
            units = int(np.prod(oriented))
            member = np.zeros(dims, dtype=bool)
            member[placement_cells(dims, oriented, offset)] = True
            member = member.ravel()
            own = np.zeros(n_slots, dtype=bool)
            both = member[table.src] & member[table.dst]
            own[table.link[both]] = True
            self_load = float(loads[own].sum())
            cross_load = float(loads[~own].sum())
            opt_geom, cur_load, opt_load, bound, ratio, certified = _advise(
                fabric, units, geometry, None
            )
        else:
            oriented = offset = geometry = ()
            units = 0
            self_load = float(loads.sum())
            cross_load = 0.0
            opt_geom, cur_load, opt_load, bound, ratio, certified = (
                None, 0.0, 0.0, 0.0, 1.0, False,
            )
        cross_total += cross_load
        jobs.append(
            JobContention(
                job_id=int(jid),
                units=units,
                geometry=geometry,
                oriented=oriented,
                offset=offset,
                self_load=self_load,
                cross_load=cross_load,
                max_link_load=float(loads.max()) if loads.size else 0.0,
                pairing_load=cur_load,
                optimal_geometry=opt_geom,
                optimal_max_load=opt_load,
                bound=bound,
                avoidable_ratio=ratio,
                certified=certified,
            )
        )

    bases: List[int] = []
    b = 0
    for a in dims:
        bases.append(b)
        b += n * a
    hotspots: List[HotspotLink] = []
    if total.size and top_hotspots > 0:
        k = min(int(top_hotspots), int((total > 0.0).sum()))
        if k > 0:
            idx = np.argpartition(total, -k)[-k:]
            idx = idx[np.argsort(-total[idx], kind="stable")]
            for i in idx:
                i = int(i)
                kdim = max(d for d in range(len(dims)) if bases[d] <= i)
                rel = i - bases[kdim]
                cell = np.unravel_index(rel // dims[kdim], dims)
                j = rel % dims[kdim]
                shares = {}
                for jid in sorted(loads_by_job):
                    share = float(np.asarray(loads_by_job[jid])[i])
                    if share > 0.0:
                        shares[int(jid)] = share
                hotspots.append(
                    HotspotLink(
                        dim=int(kdim),
                        direction=int(j),  # destination coordinate (HyperX)
                        cell=tuple(int(c) for c in cell),
                        load=float(total[i]),
                        shares=shares,
                    )
                )
    return ContentionReport(
        dims=dims,
        jobs=tuple(jobs),
        hotspots=tuple(hotspots),
        total_load=float(total.sum()),
        max_link_load=float(total.max()) if total.size else 0.0,
        cross_load=cross_total,
    )


def attribute_traffic(
    dims: Sequence[int],
    loads_by_job: Dict[int, np.ndarray],
    placements: Optional[Dict[int, Any]] = None,
    *,
    fabric=None,
    unit_node_dims: Optional[Sequence[int]] = None,
    top_hotspots: int = 5,
) -> ContentionReport:
    """Build a :class:`ContentionReport` from explicit per-job load
    tensors (each ``(D, 2, *dims)`` — e.g. a netsim result's
    ``link_loads`` split by the job that injected each flow).

    ``placements`` optionally maps job ids to
    :class:`~repro.network.allocation.Placement` records; with them the
    self/cross split and the avoidable-contention gauge are computed,
    without them the report is attribution-only (geometry fields empty).

    Passing a :class:`~repro.network.fabric.HyperXFabric` as ``fabric``
    switches to flat per-slot load vectors in the fabric's dense link
    layout (see :func:`_attribute_hyperx`); ``dims`` is then ignored in
    favour of the fabric's own.
    """
    from repro.network.fabric import HyperXFabric

    if isinstance(fabric, HyperXFabric):
        return _attribute_hyperx(
            fabric, loads_by_job, placements or {}, top_hotspots=top_hotspots
        )
    dims = tuple(int(a) for a in dims)
    D = len(dims)
    placements = placements or {}
    total = np.zeros((D, 2) + dims, dtype=np.float64)
    jobs: List[JobContention] = []
    cross_total = 0.0
    for jid in sorted(loads_by_job):
        loads = np.asarray(loads_by_job[jid], dtype=np.float64)
        if loads.shape != (D, 2) + dims:
            raise ValueError(
                f"job {jid} loads must have shape {(D, 2) + dims}; got {loads.shape}"
            )
        total += loads
        p = placements.get(jid)
        if p is not None:
            oriented = tuple(int(w) for w in p.oriented)
            offset = tuple(int(o) for o in p.offset)
            geometry = tuple(int(g) for g in p.geometry)
            units = int(np.prod(oriented))
            own = _own_link_mask(dims, oriented, offset)
            self_load = float(loads[own].sum())
            cross_load = float(loads[~own].sum())
            opt_geom, cur_load, opt_load, bound, ratio, certified = _advise(
                dims, units, geometry, unit_node_dims
            )
        else:
            oriented = offset = geometry = ()
            units = 0
            self_load = float(loads.sum())
            cross_load = 0.0
            opt_geom, cur_load, opt_load, bound, ratio, certified = (
                None, 0.0, 0.0, 0.0, 1.0, False,
            )
        cross_total += cross_load
        jobs.append(
            JobContention(
                job_id=int(jid),
                units=units,
                geometry=geometry,
                oriented=oriented,
                offset=offset,
                self_load=self_load,
                cross_load=cross_load,
                max_link_load=float(loads.max()) if loads.size else 0.0,
                pairing_load=cur_load,
                optimal_geometry=opt_geom,
                optimal_max_load=opt_load,
                bound=bound,
                avoidable_ratio=ratio,
                certified=certified,
            )
        )

    hotspots: List[HotspotLink] = []
    flat = total.ravel()
    if flat.size and top_hotspots > 0:
        k = min(int(top_hotspots), int((flat > 0.0).sum()))
        if k > 0:
            idx = np.argpartition(flat, -k)[-k:]
            idx = idx[np.argsort(-flat[idx], kind="stable")]
            for i in idx:
                kdim, direction, *cell = np.unravel_index(int(i), (D, 2) + dims)
                shares = {}
                for jid in sorted(loads_by_job):
                    share = float(np.asarray(loads_by_job[jid]).ravel()[int(i)])
                    if share > 0.0:
                        shares[int(jid)] = share
                hotspots.append(
                    HotspotLink(
                        dim=int(kdim),
                        direction=int(direction),
                        cell=tuple(int(c) for c in cell),
                        load=float(flat[int(i)]),
                        shares=shares,
                    )
                )
    return ContentionReport(
        dims=dims,
        jobs=tuple(jobs),
        hotspots=tuple(hotspots),
        total_load=float(total.sum()),
        max_link_load=float(total.max()) if total.size else 0.0,
        cross_load=cross_total,
    )


def attribute_contention(
    machine,
    *,
    unit_node_dims: Optional[Sequence[int]] = None,
    top_hotspots: int = 5,
) -> ContentionReport:
    """Decompose a live :class:`~repro.network.allocation.MachineState`
    into per-link load by owning job, with the avoidable-contention
    gauge per partition (see the module docstring).

    Each job's field is its all-to-all contention model
    (:func:`repro.network.placement.placement_loads` — the same tensor
    the scored policies stack into the background), so the per-job
    fields sum exactly to ``machine.traffic_loads()``.

    A machine built over a :class:`~repro.network.fabric.HyperXFabric`
    attributes each box's all-to-all under HyperX minimal routing
    instead (:func:`repro.network.routing.route_hyperx`); its cross
    traffic is structurally zero — minimal paths never leave the box —
    so the report's gauge is purely the geometry-internal ratio.
    """
    from repro.network.fabric import HyperXFabric
    from repro.network.placement import placement_cells, placement_loads

    dims = tuple(int(a) for a in machine.dims)
    if isinstance(getattr(machine, "fabric", None), HyperXFabric):
        from repro.network.routing import route_hyperx

        fabric = machine.fabric
        loads_by_job = {}
        for jid, p in machine.placements.items():
            member = np.zeros(dims, dtype=bool)
            member[placement_cells(dims, p.oriented, p.offset)] = True
            cells = np.stack(np.nonzero(member), axis=1)
            t = cells.shape[0]
            si = np.repeat(np.arange(t), t)
            di = np.tile(np.arange(t), t)
            keep = si != di
            loads_by_job[jid] = route_hyperx(
                fabric, cells[si[keep]], cells[di[keep]], 1.0
            )
        return attribute_traffic(
            dims,
            loads_by_job,
            dict(machine.placements),
            fabric=fabric,
            top_hotspots=top_hotspots,
        )
    loads_by_job = {
        jid: placement_loads(dims, p.oriented, p.offset)
        for jid, p in machine.placements.items()
    }
    return attribute_traffic(
        dims,
        loads_by_job,
        dict(machine.placements),
        unit_node_dims=unit_node_dims,
        top_hotspots=top_hotspots,
    )


def render_dashboard(report: ContentionReport, width: int = 30) -> str:
    """Text dashboard of a :class:`ContentionReport`: per-partition
    avoidable-contention gauges (with a bar over ``avoidable_excess``)
    and the hotspot-link breakdown."""
    lines = [
        f"contention report — machine {report.dims}",
        f"  total link load {report.total_load:.3f}, "
        f"peak {report.max_link_load:.3f}, "
        f"cross traffic {report.cross_load:.3f}",
        "",
        f"{'job':>5} {'units':>6} {'geometry':>14} {'pairing':>8} {'opt':>8} "
        f"{'avoid x':>8} {'cert':>5}  avoidable",
    ]
    max_excess = max((j.avoidable_excess for j in report.jobs), default=0.0)
    scale = max(max_excess, 1.0)
    for j in report.jobs:
        bar = "#" * int(round(width * j.avoidable_excess / scale))
        geom = "x".join(str(g) for g in j.geometry) if j.geometry else "-"
        lines.append(
            f"{j.job_id:>5} {j.units:>6} {geom:>14} {j.pairing_load:>8.3f} "
            f"{j.optimal_max_load:>8.3f} {j.avoidable_ratio:>8.2f} "
            f"{'yes' if j.certified else 'no':>5}  {bar}"
        )
    if report.hotspots:
        lines.append("")
        lines.append("hotspot links (dim, dir, cell -> load; shares by job):")
        for h in report.hotspots:
            shares = ", ".join(
                f"{jid}:{load:.3f}" for jid, load in sorted(h.shares.items())
            )
            lines.append(
                f"  d{h.dim}{'+' if h.direction == 0 else '-'} {h.cell} "
                f"-> {h.load:.3f}  [{shares}]"
            )
    return "\n".join(lines)
