"""Docs gate: doctests, docstring coverage, and README/DESIGN code blocks.

Four checks, all fatal:

1. **Doctests** — runs ``doctest.testmod`` over the audited
   ``repro.network`` modules (``python -m doctest`` cannot import package
   modules with relative imports, so the equivalent is driven here) and
   requires a minimum number of attempted examples, so deleting the
   ``TorusFabric`` / ``simulate_queue`` / ``map_ranks`` examples fails the
   gate rather than passing vacuously.
2. **Docstring coverage** — every exported (callable or class) symbol of
   ``repro.network`` carries a docstring (typing aliases exempt).
3. **Code blocks** — every ```` ```python ```` fenced block in README.md
   and DESIGN.md is executed in an isolated namespace (blocks must be
   self-contained, imports included).
4. **Quickstart == CI** — every command line in README's quickstart bash
   block (lines starting with ``pip install`` or ``PYTHONPATH=``) appears
   verbatim in ``.github/workflows/ci.yml``, so the README cannot drift
   from what CI actually runs.

Run: ``PYTHONPATH=src python tools/check_docs.py`` (CI `docs` job;
``tests/test_docs.py`` runs the same gate under tier-1).
"""

from __future__ import annotations

import doctest
import importlib
import inspect
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

AUDITED_MODULES = [
    "repro.network.geometry",
    "repro.network.fabric",
    "repro.network.hamming",
    "repro.network.isoperimetry",
    "repro.network.routing",
    "repro.network.patterns",
    "repro.network.netsim",
    "repro.network.collectives",
    "repro.network.placement",
    "repro.network.allocation",
    "repro.network.scheduler",
    "repro.network.mapping",
    "repro.network.backend",
    "repro.launch.planner",
    "repro.distributed.sharding",
    "repro.utils.env",
    "repro.obs",
    "repro.obs.trace",
    "repro.obs.metrics",
    "repro.obs.contention",
]
# TorusFabric + simulate_queue + map_ranks + the isoperimetry engine
# (cut_table / optimal_cuboid / advise_partition) examples at minimum.
MIN_DOCTEST_EXAMPLES = 12

FENCE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)


def check_doctests() -> list:
    errors = []
    attempted = 0
    for name in AUDITED_MODULES:
        mod = importlib.import_module(name)
        result = doctest.testmod(mod, verbose=False)
        attempted += result.attempted
        if result.failed:
            errors.append(f"doctest failures in {name}: {result.failed}")
    if attempted < MIN_DOCTEST_EXAMPLES:
        errors.append(
            f"only {attempted} doctest examples across audited modules "
            f"(expected >= {MIN_DOCTEST_EXAMPLES}; were examples deleted?)"
        )
    return errors


def check_docstring_coverage() -> list:
    net = importlib.import_module("repro.network")
    missing = []
    for name, obj in vars(net).items():
        if name.startswith("_") or inspect.ismodule(obj):
            continue
        if not (callable(obj) or inspect.isclass(obj)):
            continue  # constants
        if getattr(obj, "__module__", "").startswith("typing"):
            continue  # typing aliases (e.g. Geometry) cannot carry docstrings
        if not (getattr(obj, "__doc__", None) or "").strip():
            missing.append(name)
    if missing:
        return [f"exported repro.network symbols lack docstrings: {missing}"]
    return []


def check_code_blocks() -> list:
    errors = []
    for doc in ("README.md", "DESIGN.md"):
        text = (REPO / doc).read_text()
        for i, (lang, body) in enumerate(FENCE.findall(text)):
            if lang != "python":
                continue
            ns: dict = {}
            try:
                exec(compile(body, f"<{doc} block {i}>", "exec"), ns)
            except Exception as e:  # noqa: BLE001 - report and continue
                errors.append(f"{doc} python block {i} failed: {e!r}")
    return errors


def check_quickstart_matches_ci() -> list:
    readme = (REPO / "README.md").read_text()
    ci = "\n".join(
        line
        for line in (REPO / ".github" / "workflows" / "ci.yml").read_text().splitlines()
        if not line.strip().startswith("#")  # a command only in a comment is drift
    )
    commands = []
    for lang, body in FENCE.findall(readme):
        if lang not in ("bash", "sh", "console"):
            continue
        for line in body.splitlines():
            line = line.strip()
            if line.startswith("pip install") or line.startswith("PYTHONPATH="):
                commands.append(line)
    if not commands:
        return ["README.md has no quickstart bash commands to verify"]
    return [
        f"README quickstart command not found in ci.yml: {cmd!r}"
        for cmd in commands
        if cmd not in ci
    ]


def main() -> int:
    errors = (
        check_doctests()
        + check_docstring_coverage()
        + check_code_blocks()
        + check_quickstart_matches_ci()
    )
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if not errors:
        print("docs gate: doctests, docstring coverage, code blocks, quickstart==CI all OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
